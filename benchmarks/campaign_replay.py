"""Paper §4 / Figure 5 replay: the full 7.3 PB campaign under simulation.

Validates against the paper's own numbers:
  * duration ≈ 77 days (theoretical single-path floor 58 days at 1.5 GB/s);
  * both LCFs end with a complete copy;
  * relay routing carries most OLCF traffic (LLNL read once per dataset);
  * per-route average rates in the neighborhood of Table 3;
  * fault skew: most transfers fault-free, a few with many (Figure 6).

Full scale is 2291 datasets; ``--scale`` trades fidelity for runtime
(benchmarks/run.py uses 0.25 to stay within CI budgets; the duration figure
is scale-invariant because bandwidths and totals shrink together only when
--scale-bytes is also given — by default only file counts shrink).

``--compare-engines`` additionally replays the paper-2022 scenario under the
fixed-step driver AND the event-driven core (``repro.scenarios.events``) and
records the wall-clock speedup into ``BENCH_scenarios.json``.

``--scaling`` sweeps the catalog size (default n ∈ {48, 512, 2291, 8192,
20480} synthetic datasets) under the event engine and records
wall-clock / iterations / events-per-second per point into
``BENCH_scenarios.json`` — the O(active) acceptance evidence: events/s (and
µs per iteration) must stay flat as the catalog grows.  ``--scenario
mega-campaign`` replays the ≥20k-dataset four-site registry scenario.

``--checkpoint-bench`` measures the durable-checkpoint tax: a cadenced
snapshot run vs a bare run, with the (required) bit-identical-trajectory
verdict, mean write latency, and snapshot size recorded under the
``checkpointing`` key of ``BENCH_scenarios.json``.

``--federation-bench`` replays the overlapped two-campaign federation
(``federation-paper-twice``) under both engines, checks the shared
source-egress cap at every tick, compares the span against the serial
back-to-back variant, and records everything (per-member digests included)
under the ``federation`` key of ``BENCH_scenarios.json``.

``--demand-bench`` replays ``esgf-serving`` popular-first (both engines),
the catalog-order ablation, and the no-traffic comparator, and records the
serving SLOs plus the popular-first-beats-catalog-order verdict under the
``demand`` key of ``BENCH_scenarios.json``.

``--ensemble-bench`` gates the batched ensemble engine's worlds/sec
scaling: a 256-lane lockstep pass of ``ensemble-paper-bands`` must beat
256 sequential scalar replays by >=20x, with every sampled lane matching
its scalar trajectory bit-for-bit, recorded under the ``ensemble`` key of
``BENCH_scenarios.json``.

``--integrity-bench`` replays ``scrub-and-repair`` (both engines), the
``bit-rot-paper`` no-scrub ablation, and the corruption-free comparator,
and records the integrity summaries plus the ends-clean / repairs-converge
/ exposure / repair-tax verdicts under the ``integrity`` key of
``BENCH_scenarios.json``.

``--obs-bench`` gates the flight recorder: a paper-2022 replay with trace +
metrics on must stay within 1.10x the obs-off wall (same-process
min-of-repeats) with a bit-identical trajectory tuple, recorded under the
``obs`` key of ``BENCH_scenarios.json``.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.campaign import CampaignConfig, run_campaign
from repro.obs.profile import PhaseProfiler

SCALING_NS = (48, 512, 2291, 8192, 20480)


def replay(n_datasets: int = 2291, scale: float = 1.0, seed: int = 0,
           step_s: float = 1800.0):
    cfg = CampaignConfig(n_datasets=n_datasets, scale=scale, seed=seed,
                         step_s=step_s)
    t0 = time.time()
    rep = run_campaign(cfg)
    wall = time.time() - t0
    out = {
        "wall_s": wall,
        "duration_days": rep.duration_days,
        "floor_days": rep.floor_days,
        "paper_duration_days": 77.0,
        "paper_floor_days": 58.0,
        "complete_at_both": all(v >= rep.total_bytes * 0.999
                                for v in rep.bytes_at.values()),
        "per_route_gbps": {f"{a}->{b}": round(v, 3)
                           for (a, b), v in rep.per_route_gbps.items()},
        "per_route_transfers": {f"{a}->{b}": v
                                for (a, b), v in rep.per_route_transfers.items()},
        "paper_table3_gbps": {"LLNL->ALCF": 0.648, "LLNL->OLCF": 0.662,
                              "ALCF->OLCF": 1.706, "OLCF->ALCF": 2.352},
        "faults_total": rep.faults_total,
        "paper_faults_total": 4086,
        "faults_mean": round(rep.faults_per_transfer_mean, 2),
        "faults_max": rep.faults_per_transfer_max,
        "quarantined": rep.quarantined,
        "notifications": len(rep.notifications),
    }
    return out, rep


def compare_engines(n_datasets: int = 48, scale: float = 1.0, seed: int = 0):
    """Step-driven vs event-driven replay of the paper-2022 scenario: same
    catalog, calendar, and fault seeds; records wall clock, driver
    iterations, and the behavior deltas that must stay small."""
    from repro.scenarios.events import EngineStats, run_scenario

    results = {}
    for engine in ("step", "events"):
        stats = EngineStats()
        t0 = time.time()
        rep = run_scenario("paper-2022", engine=engine, scale=scale,
                           seed=seed, n_datasets=n_datasets, stats=stats)
        results[engine] = {
            "wall_s": round(time.time() - t0, 3),
            "iterations": stats.iterations,
            "duration_days": round(rep.duration_days, 3),
            "faults_total": rep.faults_total,
            "faults_max": rep.faults_per_transfer_max,
            "quarantined": rep.quarantined,
        }
    step, ev = results["step"], results["events"]
    return {
        "n_datasets": n_datasets,
        "scale": scale,
        "seed": seed,
        "step": step,
        "events": ev,
        "speedup": round(step["wall_s"] / max(ev["wall_s"], 1e-9), 2),
        "duration_delta_pct": round(
            100.0 * abs(ev["duration_days"] - step["duration_days"])
            / max(step["duration_days"], 1e-9), 3),
    }


def scaling_point(n_datasets: int, scenario: str = "paper-2022",
                  seed: int = 0, scale: float = 1.0) -> dict:
    """One event-engine replay at catalog size ``n_datasets``, reduced to
    the scaling metrics: wall clock, driver iterations, events/s, and the
    per-iteration cost that must stay flat in catalog size."""
    from repro.scenarios.events import EngineStats, run_scenario
    stats = EngineStats()
    t0 = time.time()
    rep = run_scenario(scenario, engine="events", scale=scale, seed=seed,
                       n_datasets=n_datasets, stats=stats)
    wall = time.time() - t0
    return {
        "n_datasets": n_datasets,
        "wall_s": round(wall, 3),
        "iterations": stats.iterations,
        "events_per_s": round(stats.iterations / max(wall, 1e-9), 1),
        "us_per_iteration": round(1e6 * wall / max(stats.iterations, 1), 1),
        "duration_days": round(rep.duration_days, 3),
        "faults_total": rep.faults_total,
        "quarantined": rep.quarantined,
    }


def checkpoint_bench(n_datasets: int = 48, every: int = 25, seed: int = 0,
                     workdir: str = None) -> dict:
    """Cost of durable checkpointing on the paper-2022 event replay: run
    uninterrupted, then again with a snapshot every ``every`` iterations,
    and report write cadence cost, snapshot size, and — the load-bearing
    bit — that the checkpointed trajectory is identical to the bare one."""
    import shutil
    import tempfile

    from repro.core.snapshot import Checkpointer, trajectory_summary
    from repro.scenarios.events import EngineStats, run_world
    from repro.scenarios.registry import get_scenario

    spec = get_scenario("paper-2022")
    world = spec.build(seed=seed, n_datasets=n_datasets)
    stats = EngineStats()
    t0 = time.time()
    rep = run_world(world, stats=stats)
    bare_wall = time.time() - t0
    ref = trajectory_summary(rep, stats, world.table)

    own_dir = workdir is None
    workdir = workdir or tempfile.mkdtemp(prefix="ckpt-bench-")
    world2 = spec.build(seed=seed, n_datasets=n_datasets)
    stats2 = EngineStats()
    ck = Checkpointer(workdir, every=every)
    t0 = time.time()
    rep2 = run_world(world2, stats=stats2, checkpointer=ck)
    wall = time.time() - t0
    res = trajectory_summary(rep2, stats2, world2.table)
    if own_dir:
        shutil.rmtree(workdir, ignore_errors=True)
    return {
        "n_datasets": n_datasets,
        "every": every,
        "iterations": stats2.iterations,
        "writes": ck.writes,
        "write_ms_mean": round(1000.0 * ck.write_s / max(1, ck.writes), 2),
        "snapshot_bytes": ck.last_bytes,
        "bare_wall_s": round(bare_wall, 3),
        "wall_s": round(wall, 3),
        "overhead_pct": round(100.0 * (wall - bare_wall) / max(bare_wall, 1e-9),
                              1),
        "identical_to_bare": res == ref,
        "succeeded_digest": ref["succeeded_digest"],
    }


def federation_bench(n_datasets: int = 32, seed: int = 0,
                     repeats: int = 3) -> dict:
    """The federation acceptance experiment, benchmarked: replay the
    overlapped two-campaign federation under BOTH engines (determinism and
    wall clock recorded like ``engine_comparison``), the serial back-to-back
    variant, and the relay-assisted single-campaign comparator.  Records:

      * per-engine iterations / span / faults / per-member digests — the
        determinism invariants the regression gate pins;
      * ``source_cap_ok`` — at every transport tick of the overlapped run,
        aggregate LLNL egress (sum of per-route fair shares × actives) never
        exceeded the LLNL ``read_bw``;
      * ``overlap_beats_serial`` — the overlapped federation's span in
        campaign days beats the serial variant's.
    """
    from repro.core.snapshot import federation_trajectory_summary
    from repro.scenarios.events import EngineStats, run_world
    from repro.scenarios.registry import get_scenario

    results = {}
    for engine in ("step", "events"):
        # wall clock is min-of-``repeats`` (sub-second replays are noisy on
        # shared CI runners); trajectories are identical across repeats
        walls = []
        for _ in range(max(1, repeats)):
            world = get_scenario("federation-paper-twice").build(
                seed=seed, n_datasets=n_datasets)
            transport = world.shared.transport
            read_bw = world.shared.graph.sites["LLNL"].read_bw
            cap = {"ok": True, "max_frac": 0.0}
            orig = transport._route_rates

            def route_rates(movers, _orig=orig, _cap=cap):
                rates = _orig(movers)
                active = {}
                for x in movers:
                    r = (x.source, x.destination)
                    active[r] = active.get(r, 0) + 1
                egress = sum(rates[r] * n for r, n in active.items()
                             if r[0] == "LLNL")
                _cap["max_frac"] = max(_cap["max_frac"], egress / read_bw)
                if egress > read_bw * (1 + 1e-9):
                    _cap["ok"] = False
                return rates

            transport._route_rates = route_rates
            stats = EngineStats()
            t0 = time.time()
            rep = run_world(world, engine=engine, stats=stats)
            walls.append(time.time() - t0)
        summ = federation_trajectory_summary(rep, stats, world)
        results[engine] = {
            "wall_s": round(min(walls), 3),
            "iterations": stats.iterations,
            "span_days": round(rep.span_days, 3),
            "faults_total": sum(m.faults_total for m in rep.members.values()),
            "source_cap_ok": cap["ok"],
            "source_cap_max_frac": round(cap["max_frac"], 4),
            "members": {label: {
                "sim_days": round(m["sim_days"], 3),
                "succeeded_digest": m["succeeded_digest"],
            } for label, m in summ["members"].items()},
        }

    serial_world = get_scenario("federation-paper-serial").build(
        seed=seed, n_datasets=n_datasets)
    serial_stats = EngineStats()
    serial_rep = run_world(serial_world, engine="events", stats=serial_stats)

    relay_stats = EngineStats()
    relay_world = get_scenario("paper-2022").build(seed=seed,
                                                   n_datasets=n_datasets)
    relay_rep = run_world(relay_world, engine="events", stats=relay_stats)

    step, ev = results["step"], results["events"]
    return {
        "scenario": "federation-paper-twice",
        "n_datasets": n_datasets,
        "seed": seed,
        "step": step,
        "events": ev,
        "speedup": round(step["wall_s"] / max(ev["wall_s"], 1e-9), 2),
        "serial_span_days": round(serial_rep.span_days, 3),
        "relay_single_days": round(relay_rep.duration_days, 3),
        "overlap_beats_serial": ev["span_days"] < serial_rep.span_days,
    }


# demand-bench shape: small enough for CI, enough catalog + traffic that the
# popular-first ordering measurably moves the serving SLOs
DEMAND_SHAPE = dict(n_datasets=32, scale=0.02)


def demand_bench(seed: int = 0) -> dict:
    """The demand-engine acceptance experiment: replay esgf-serving
    popular-first (both engines), the catalog-order ablation, and the
    no-traffic comparator, recording each arm's determinism tuple
    (iterations, float-exact sim days, faults, succeeded digest) plus the
    serving SLOs.  Carries the headline verdicts:

      * ``popular_first_beats_catalog_order`` — popularity-driven
        replication reaches a better overall hit-rate and an
        as-early-or-earlier time-to-90%-hit-rate day than catalog-order
        replication under identical traffic;
      * ``traffic_tax_ok`` — serving 2M users while replicating costs at
        most 50% extra campaign days over the no-traffic baseline.
    """
    from repro.core.snapshot import trajectory_summary
    from repro.demand.spec import NO_DEMAND
    from repro.scenarios.events import EngineStats, run_world
    from repro.scenarios.registry import get_scenario

    arms = {
        "popular_first": (get_scenario("esgf-serving"), ("events", "step")),
        "catalog_order": (get_scenario("popular-first-vs-catalog-order"),
                          ("events",)),
        "no_traffic": (get_scenario("esgf-serving").with_demand(NO_DEMAND),
                       ("events",)),
    }
    out = {"seed": seed, "shape": dict(DEMAND_SHAPE), "arms": {}}
    for label, (spec, engines) in arms.items():
        for engine in engines:
            world = spec.build(seed=seed, **DEMAND_SHAPE)
            stats = EngineStats()
            t0 = time.time()
            rep = run_world(world, engine=engine, stats=stats)
            wall = time.time() - t0
            traj = trajectory_summary(rep, stats, world.table)
            key = label if engine == "events" else f"{label}_{engine}"
            arm = {
                "wall_s": round(wall, 3),
                "iterations": stats.iterations,
                "sim_days": rep.duration_days,
                "faults_total": rep.faults_total,
                "quarantined": rep.quarantined,
                "succeeded_digest": traj["succeeded_digest"],
            }
            if world.demand is not None:
                s = world.demand.summary()
                arm["serving"] = {
                    k: s[k] for k in
                    ("waves", "requests", "hit_rate", "cache_hit_rate",
                     "source_reads", "p50_s", "p99_s", "day90",
                     "final_day_hit_rate")}
            out["arms"][key] = arm
            print(f"{key:20} {arm['sim_days']:8.3f} d "
                  f"({arm['wall_s']:.2f}s)"
                  + (f"  hit={arm['serving']['hit_rate']*100:.1f}% "
                     f"day90={arm['serving']['day90']} "
                     f"p99={arm['serving']['p99_s']}s"
                     if "serving" in arm else ""))
    pf = out["arms"]["popular_first"]["serving"]
    co = out["arms"]["catalog_order"]["serving"]
    inf = float("inf")
    out["popular_first_beats_catalog_order"] = (
        pf["hit_rate"] > co["hit_rate"]
        and (inf if pf["day90"] is None else pf["day90"])
        <= (inf if co["day90"] is None else co["day90"]))
    out["traffic_tax_ok"] = (
        out["arms"]["popular_first"]["sim_days"]
        <= out["arms"]["no_traffic"]["sim_days"] * 1.5)
    return out


# integrity-bench shape: small enough for CI, enough landed petabytes that
# the accelerated latent-corruption rate draws a handful of corrupt replicas
INTEGRITY_SHAPE = dict(n_datasets=32, scale=0.02)


def integrity_bench(seed: int = 0) -> dict:
    """The silent-corruption acceptance experiment: replay scrub-and-repair
    (both engines), the no-scrub bit-rot ablation, and the corruption-free
    comparator, recording each arm's determinism tuple plus the integrity
    summary (detections, repairs, exposure replica-days, surviving at-risk
    bytes).  Carries the headline verdicts:

      * ``ends_clean`` — every scrub arm finishes with zero corrupt
        replicas (detected > 0, repaired == detected, clean);
      * ``repairs_converge`` — the scrub arm's final SUCCEEDED replica set
        is identical (set digest) to the corruption-free run's end state;
      * ``ablation_survives_corrupt`` — with scrubbing disabled the same
        draws leave silently corrupt replicas at campaign end;
      * ``exposure_ok`` — total at-risk exposure stays under 3 scrub
        intervals per detected replica;
      * ``repair_tax_ok`` — scrubbing + repairs cost at most 75% extra
        campaign days over the corruption-free baseline.
    """
    from repro.core.scrub import NO_SCRUB
    from repro.core.snapshot import replica_set_digest, trajectory_summary
    from repro.scenarios.events import EngineStats, run_world
    from repro.scenarios.registry import get_scenario

    arms = {
        "scrub_repair": (get_scenario("scrub-and-repair"),
                         ("events", "step")),
        "no_scrub": (get_scenario("bit-rot-paper"), ("events",)),
        "clean": (get_scenario("scrub-and-repair").with_scrub(NO_SCRUB),
                  ("events",)),
    }
    out = {"seed": seed, "shape": dict(INTEGRITY_SHAPE), "arms": {}}
    for label, (spec, engines) in arms.items():
        for engine in engines:
            world = spec.build(seed=seed, **INTEGRITY_SHAPE)
            stats = EngineStats()
            t0 = time.time()
            rep = run_world(world, engine=engine, stats=stats)
            wall = time.time() - t0
            traj = trajectory_summary(rep, stats, world.table)
            key = label if engine == "events" else f"{label}_{engine}"
            arm = {
                "wall_s": round(wall, 3),
                "iterations": stats.iterations,
                "sim_days": rep.duration_days,
                "faults_total": rep.faults_total,
                "quarantined": rep.quarantined,
                "succeeded_digest": traj["succeeded_digest"],
                "replica_digest": replica_set_digest(world.table),
            }
            if world.scrub is not None:
                arm["integrity"] = world.scrub.summary()
            out["arms"][key] = arm
            print(f"{key:20} {arm['sim_days']:8.3f} d "
                  f"({arm['wall_s']:.2f}s)"
                  + (f"  detected={arm['integrity']['detected']} "
                     f"repaired={arm['integrity']['repaired']} "
                     f"exposure={arm['integrity']['exposure_days']}d "
                     f"{'CLEAN' if arm['integrity']['clean'] else 'AT RISK'}"
                     if "integrity" in arm else ""))
    sr = out["arms"]["scrub_repair"]
    interval = get_scenario("scrub-and-repair").scrub.interval_days
    out["ends_clean"] = all(
        a["integrity"]["clean"] and a["integrity"]["detected"] > 0
        and a["integrity"]["repaired"] == a["integrity"]["detected"]
        for a in (sr, out["arms"]["scrub_repair_step"]))
    out["repairs_converge"] = (
        sr["replica_digest"] == out["arms"]["clean"]["replica_digest"])
    ab = out["arms"]["no_scrub"]["integrity"]
    out["ablation_survives_corrupt"] = (
        not ab["clean"] and ab["data_at_risk_bytes"] > 0)
    out["exposure_ok"] = (
        sr["integrity"]["exposure_days"]
        <= 3.0 * interval * max(1, sr["integrity"]["detected"]))
    out["repair_tax_ok"] = (
        sr["sim_days"] <= out["arms"]["clean"]["sim_days"] * 1.75)
    return out


# policy-bench shapes: small enough for CI, large enough that the task-
# dispatch overhead the control plane amortizes actually dominates static
POLICY_SHAPES = {
    "small-file-storm": dict(n_datasets=200, scale=0.2),
    "mixed-bundle-paper": dict(n_datasets=24, scale=0.01),
    # enough bytes (0.73 PB) that the kneed source bandwidth — not the
    # maintenance calendar — bounds the campaign
    "lossy-route-tuning": dict(n_datasets=32, scale=0.1),
}


def policy_bench(seed: int = 0) -> dict:
    """The control-plane acceptance experiment: replay each policy scenario
    under its declared adaptive policy AND under the naive static
    per-dataset baseline, and record the determinism tuple (iterations,
    float-exact sim days, faults, succeeded digest) plus wall clock for
    each.  ``small-file-storm`` additionally runs both driver engines per
    policy, and carries the headline verdict: adaptive bundling must finish
    in no more simulated campaign days than the static baseline — the
    simulator's quantitative version of 'Globus-style bundling beats
    scripted per-dataset submission on small-file-heavy catalogs'."""
    from repro.control.policy import STATIC_POLICY
    from repro.core.snapshot import trajectory_summary
    from repro.scenarios.events import EngineStats, run_world
    from repro.scenarios.registry import get_scenario

    out = {"seed": seed,
           "shapes": {k: dict(v) for k, v in POLICY_SHAPES.items()},
           "scenarios": {}}
    for name, shape in POLICY_SHAPES.items():
        block = {}
        engines = (("events", "step") if name == "small-file-storm"
                   else ("events",))
        for label in ("static", "adaptive"):
            spec = get_scenario(name)
            if label == "static":
                spec = spec.with_policy(STATIC_POLICY)
            for engine in engines:
                world = spec.build(seed=seed, **shape)
                stats = EngineStats()
                t0 = time.time()
                rep = run_world(world, engine=engine, stats=stats)
                wall = time.time() - t0
                traj = trajectory_summary(rep, stats, world.table)
                key = label if engine == "events" else f"{label}_{engine}"
                block[key] = {
                    "wall_s": round(wall, 3),
                    "iterations": stats.iterations,
                    "sim_days": rep.duration_days,
                    "faults_total": rep.faults_total,
                    "quarantined": rep.quarantined,
                    "succeeded_digest": traj["succeeded_digest"],
                }
        block["adaptive_beats_static"] = (
            block["adaptive"]["sim_days"] <= block["static"]["sim_days"])
        out["scenarios"][name] = block
        print(f"{name:20} static {block['static']['sim_days']:8.3f} d "
              f"({block['static']['wall_s']:.2f}s) vs adaptive "
              f"{block['adaptive']['sim_days']:8.3f} d "
              f"({block['adaptive']['wall_s']:.2f}s)"
              + ("  ADAPTIVE WINS" if block["adaptive_beats_static"]
                 else "  !! static wins"))
    return out


def ensemble_bench(n_lanes: int = 256, scale: float = 0.002,
                   n_datasets: int = 4, sample: int = 8,
                   min_speedup: float = 20.0) -> dict:
    """Worlds/sec scaling gate for the batched ensemble engine: one
    N-lane lockstep pass of ``ensemble-paper-bands`` must beat N
    sequential scalar replays of the identical lanes by ``min_speedup``x.

    Protocol: ``sample`` lanes replay through the scalar event engine
    first (sequentially, the way a seed sweep runs without the lanes
    engine) and project to N; the lanes engine then runs the full
    ensemble twice (best-of-2 — the first pass pays allocator warm-up).
    Speedup is a same-process, same-machine ratio, so runner speed
    cancels.  Every sampled lane must match its lanes-engine row on the
    full trajectory tuple — the bit-identity contract, enforced here on
    ``sample`` lanes, not just lane 0."""
    import dataclasses

    from repro.ensemble.engine import run_ensemble, scalar_lane
    from repro.ensemble.run import GATE_FIELDS
    from repro.scenarios.registry import get_scenario

    espec = dataclasses.replace(get_scenario("ensemble-paper-bands"),
                                n_lanes=n_lanes)
    lanes = espec.lane_specs()
    t0 = time.time()
    refs = [scalar_lane(spec, seed, label, scale, n_datasets)
            for spec, seed, label in lanes[:sample]]
    scalar_sample_s = time.time() - t0
    scalar_projected_s = scalar_sample_s / sample * n_lanes

    walls = []
    for _ in range(2):
        t0 = time.time()
        res = run_ensemble(espec, scale=scale, n_datasets=n_datasets)
        walls.append(time.time() - t0)
    lanes_wall_s = min(walls)

    mismatches = {}
    for i, ref in enumerate(refs):
        got = res.lane(i)
        diff = {f: {"scalar": getattr(ref, f), "lanes": getattr(got, f)}
                for f in GATE_FIELDS if getattr(ref, f) != getattr(got, f)}
        if diff:
            mismatches[i] = diff
    speedup = scalar_projected_s / max(lanes_wall_s, 1e-9)
    doc = {
        "ensemble": espec.name, "n_lanes": n_lanes, "scale": scale,
        "n_datasets": n_datasets, "engine": res.engine,
        "backend": res.backend, "sample": sample,
        "scalar_sample_s": round(scalar_sample_s, 3),
        "scalar_projected_s": round(scalar_projected_s, 3),
        "lanes_wall_s": round(lanes_wall_s, 3),
        "speedup": round(speedup, 1),
        "min_speedup": min_speedup,
        "lanes_identical": not mismatches,
        "mismatches": mismatches,
        "lane0": {f: getattr(res.lane(0), f)
                  for f in GATE_FIELDS if f != "bytes_at"},
        "bands": res.bands,
        "gate_ok": (not mismatches) and speedup >= min_speedup,
    }
    print(f"ensemble {espec.name}: {n_lanes} lanes in {lanes_wall_s:.3f}s "
          f"vs {scalar_projected_s:.2f}s projected sequential "
          f"({scalar_sample_s:.2f}s for {sample}) -> {speedup:.1f}x "
          + ("OK" if doc["gate_ok"]
             else f"!! gate FAILED (need >={min_speedup}x, "
                  f"identical={not mismatches})"))
    return doc


# promoted to src/repro/obs/profile.py; the bench keeps this alias so any
# external caller of benchmarks.campaign_replay._PhaseProfiler still works
_PhaseProfiler = PhaseProfiler


def profile_run(scenario: str = "paper-2022", n_datasets: int = None,
                seed: int = 0, scale: float = 1.0) -> dict:
    """One instrumented event-engine replay split into per-phase buckets:
    sched (dispatch/poll), transport (tick + next-event hints), table
    (row/index churn, charged exclusively), control/demand/scrub (the
    opt-in planes), and driver (the run_world loop remainder).  Thin
    wrapper over ``repro.obs.profile.PhaseProfiler``."""
    from repro.scenarios.events import EngineStats, run_scenario

    stats = EngineStats()
    t0 = time.time()
    with PhaseProfiler() as prof:
        prof.instrument_standard()
        run_scenario(scenario, engine="events", scale=scale, seed=seed,
                     n_datasets=n_datasets, stats=stats)
    wall = time.time() - t0
    doc = prof.report(wall)
    return {
        "scenario": scenario,
        "n_datasets": n_datasets,
        "seed": seed,
        "wall_s": doc["wall_s"],
        "iterations": stats.iterations,
        "phases_s": doc["phases_s"],
        "phases_pct": doc["phases_pct"],
    }


def obs_bench(n_datasets: int = 2291, seed: int = 0, scale: float = 1.0,
              repeats: int = 3, max_overhead: float = 1.10) -> dict:
    """The flight-recorder overhead + determinism gate: paper-2022 replayed
    obs-off and obs-on (trace + metrics, in-memory — no sink I/O in the
    measured loop), min-of-repeats walls, and the trajectory tuples that
    must match bit-exactly.  Both arms run in this same process, so the
    ratio cancels machine speed and the gate travels."""
    from repro.core.snapshot import trajectory_summary
    from repro.obs.spec import FULL_OBS
    from repro.scenarios.events import EngineStats, run_world
    from repro.scenarios.registry import get_scenario

    spec_off = get_scenario("paper-2022")
    spec_on = spec_off.with_obs(FULL_OBS)
    arms = {}
    # interleave the arms so clock drift (thermal, background load) hits
    # both equally instead of biasing whichever ran second
    for _ in range(repeats):
        for arm, spec in (("obs_off", spec_off), ("obs_on", spec_on)):
            world = spec.build(scale=scale, seed=seed, n_datasets=n_datasets)
            stats = EngineStats()
            t0 = time.perf_counter()
            rep = run_world(world, engine="events", stats=stats)
            wall = time.perf_counter() - t0
            traj = trajectory_summary(rep, stats, world.table)
            best = arms.get(arm)
            if best is None or wall < best["wall_s"]:
                arms[arm] = {"wall_s": wall, "trajectory": traj}
    for best in arms.values():
        best["wall_s"] = round(best["wall_s"], 3)
    ratio = arms["obs_on"]["wall_s"] / max(arms["obs_off"]["wall_s"], 1e-9)
    identical = arms["obs_on"]["trajectory"] == arms["obs_off"]["trajectory"]
    doc = {
        "scenario": "paper-2022",
        "n_datasets": n_datasets,
        "seed": seed,
        "scale": scale,
        "repeats": repeats,
        "obs_off": arms["obs_off"],
        "obs_on": arms["obs_on"],
        "overhead_ratio": round(ratio, 3),
        "max_overhead": max_overhead,
        "obs_identical": identical,
        "gate_ok": identical and ratio <= max_overhead,
    }
    print(f"obs paper-2022 n={n_datasets}: off={arms['obs_off']['wall_s']:.3f}s "
          f"on={arms['obs_on']['wall_s']:.3f}s -> {ratio:.3f}x "
          + ("OK" if doc["gate_ok"]
             else f"!! gate FAILED (need <={max_overhead}x, "
                  f"identical={identical})"))
    return doc


def scaling(ns=SCALING_NS, scenario: str = "paper-2022", seed: int = 0) -> dict:
    rows = []
    for n in ns:
        row = scaling_point(n, scenario=scenario, seed=seed)
        rows.append(row)
        print(f"n={n:6d}  wall={row['wall_s']:8.2f}s  "
              f"iters={row['iterations']:7d}  "
              f"{row['events_per_s']:8.1f} ev/s  "
              f"{row['us_per_iteration']:7.1f} us/iter  "
              f"{row['duration_days']:7.2f} d")
    return {"scenario": scenario, "seed": seed, "points": rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", type=int, default=2291)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--scenario", default="paper-2022")
    ap.add_argument("--out", default=None)
    ap.add_argument("--compare-engines", action="store_true",
                    help="benchmark step vs event engine on paper-2022 and "
                         "record the speedup in BENCH_scenarios.json")
    ap.add_argument("--checkpoint-bench", action="store_true",
                    help="measure durable-checkpoint overhead (cadenced "
                         "snapshots vs bare run) and record it in "
                         "BENCH_scenarios.json")
    ap.add_argument("--checkpoint-every", type=int, default=25,
                    help="snapshot cadence for --checkpoint-bench")
    ap.add_argument("--policy-bench", action="store_true",
                    help="compare the control plane's adaptive policies "
                         "against the static per-dataset baseline on the "
                         "policy scenarios and record it in "
                         "BENCH_scenarios.json")
    ap.add_argument("--demand-bench", action="store_true",
                    help="compare popular-first vs catalog-order vs "
                         "no-traffic serving on esgf-serving and record it "
                         "in BENCH_scenarios.json")
    ap.add_argument("--integrity-bench", action="store_true",
                    help="compare scrub-and-repair vs the no-scrub bit-rot "
                         "ablation vs the corruption-free baseline and "
                         "record it in BENCH_scenarios.json")
    ap.add_argument("--federation-bench", action="store_true",
                    help="benchmark the overlapped two-campaign federation "
                         "vs its serial variant (both engines, source-cap "
                         "check) and record it in BENCH_scenarios.json")
    ap.add_argument("--ensemble-bench", action="store_true",
                    help="gate the batched ensemble engine's worlds/sec "
                         "scaling (N=256 lockstep vs N sequential scalar "
                         "replays, bit-identity enforced on sampled lanes) "
                         "and record it in BENCH_scenarios.json")
    ap.add_argument("--obs-bench", action="store_true",
                    help="gate the flight recorder: obs-on paper-2022 "
                         "replay <= 1.10x obs-off wall with a bit-identical "
                         "trajectory, recorded in BENCH_scenarios.json")
    ap.add_argument("--ensemble-lanes", type=int, default=256,
                    help="lane count for --ensemble-bench")
    ap.add_argument("--min-speedup", type=float, default=20.0,
                    help="speedup floor for --ensemble-bench")
    ap.add_argument("--scaling", action="store_true",
                    help="replay --scenario at increasing catalog sizes and "
                         "record the scaling curve in BENCH_scenarios.json")
    ap.add_argument("--scaling-ns", default=None,
                    help="comma-separated catalog sizes for --scaling "
                         f"(default {','.join(map(str, SCALING_NS))})")
    ap.add_argument("--profile", action="store_true",
                    help="instrumented replay splitting wall time into "
                         "sched/transport/table/control/demand/scrub/driver "
                         "buckets; alone it profiles --scenario at "
                         "--datasets, with --scaling it attaches the "
                         "breakdown at the largest sweep point")
    ap.add_argument("--bench-out", default="BENCH_scenarios.json")
    args = ap.parse_args()
    from repro.scenarios.sweep import emit_bench
    if args.scaling:
        ns = (tuple(int(s) for s in args.scaling_ns.split(","))
              if args.scaling_ns else SCALING_NS)
        doc = scaling(ns, scenario=args.scenario)
        if args.profile:
            doc["profile"] = profile_run(args.scenario, n_datasets=max(ns))
            print(json.dumps(doc["profile"], indent=2))
        key = ("scaling" if args.scenario == "paper-2022"
               else f"scaling_{args.scenario}")
        emit_bench([], path=args.bench_out, extra={key: doc})
        return
    if args.profile:
        datasets = args.datasets if args.datasets != 2291 else None
        doc = profile_run(args.scenario, n_datasets=datasets,
                          scale=args.scale)
        key = ("profile" if args.scenario == "paper-2022"
               else f"profile_{args.scenario}")
        emit_bench([], path=args.bench_out, extra={key: doc})
        print(json.dumps(doc, indent=2))
        return
    if args.obs_bench:
        doc = obs_bench(n_datasets=args.datasets, scale=args.scale)
        emit_bench([], path=args.bench_out, extra={"obs": doc})
        print(json.dumps(doc, indent=2))
        if not doc["gate_ok"]:
            raise SystemExit(1)
        return
    if args.ensemble_bench:
        doc = ensemble_bench(n_lanes=args.ensemble_lanes,
                             min_speedup=args.min_speedup)
        emit_bench([], path=args.bench_out, extra={"ensemble": doc})
        print(json.dumps({k: v for k, v in doc.items() if k != "bands"},
                         indent=2))
        if not doc["gate_ok"]:
            raise SystemExit(1)
        return
    if args.policy_bench:
        doc = policy_bench()
        emit_bench([], path=args.bench_out, extra={"policy": doc})
        print(json.dumps(doc, indent=2))
        return
    if args.demand_bench:
        doc = demand_bench()
        emit_bench([], path=args.bench_out, extra={"demand": doc})
        print(json.dumps(doc, indent=2))
        return
    if args.integrity_bench:
        doc = integrity_bench()
        emit_bench([], path=args.bench_out, extra={"integrity": doc})
        print(json.dumps(doc, indent=2))
        return
    if args.federation_bench:
        doc = federation_bench(n_datasets=min(args.datasets, 32))
        emit_bench([], path=args.bench_out, extra={"federation": doc})
        print(json.dumps(doc, indent=2))
        return
    if args.checkpoint_bench:
        doc = checkpoint_bench(n_datasets=min(args.datasets, 48),
                               every=args.checkpoint_every)
        emit_bench([], path=args.bench_out, extra={"checkpointing": doc})
        print(json.dumps(doc, indent=2))
        return
    if args.compare_engines:
        cmp = compare_engines(n_datasets=min(args.datasets, 48),
                              scale=args.scale)
        emit_bench([], path=args.bench_out,
                   extra={"engine_comparison": cmp})
        print(json.dumps(cmp, indent=2))
        return
    if args.scenario != "paper-2022":
        # non-paper scenarios replay through the event engine
        out = scaling_point(args.datasets, scenario=args.scenario,
                            scale=args.scale)
        print(json.dumps(out, indent=2))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2)
        return
    out, rep = replay(args.datasets, args.scale)
    print(json.dumps(out, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)


if __name__ == "__main__":
    main()
