"""Regenerate the roofline table inside EXPERIMENTS.md from the dry-run
artifacts (idempotent; keyed on the <!-- ROOFLINE_TABLE --> marker)."""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import markdown_table, run

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
MARK = "<!-- ROOFLINE_TABLE -->"


def main():
    cells = run(write=True)
    table = markdown_table(cells, pod=256)
    n2 = sum(1 for c in cells if c["chips"] == 512)
    blob = (f"{MARK}\n{table}\n\n*(single-pod mesh; {n2} matching multi-pod "
            "cells in `experiments/roofline.json` — the pod axis adds the "
            "once-per-step DP gradient reduction and halves per-chip batch)*")
    text = open(EXP).read()
    pattern = re.compile(re.escape(MARK) + r"(?:.*?\n\n\*\(single-pod[^\n]*\n?)?",
                         re.S)
    if MARK in text:
        # replace from marker through the previous injected table (up to the
        # next section header)
        pre, rest = text.split(MARK, 1)
        nxt = rest.find("\nObservations:")
        text = pre + blob + rest[nxt:]
    open(EXP, "w").write(text)
    print(f"injected {len(cells)} cells")


if __name__ == "__main__":
    main()
