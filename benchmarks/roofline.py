"""Roofline analysis over the dry-run artifacts (paper deliverable g).

Reads experiments/dryrun/*.json (written by ``repro.launch.dryrun``), computes
the three roofline terms per (arch × shape × mesh):

    compute    = FLOPs            / (chips × 197e12 FLOP/s)
    memory     = HBM bytes        / (chips × 819e9 B/s)
    collective = wire bytes/chip  / 50e9 B/s (per-link ICI)

FLOPs/HBM bytes come from the analytic model of the lowered program
(launch/analytic.py) because XLA-CPU cost_analysis counts while-bodies once
(verified; both numbers are recorded).  Wire bytes are parsed from the
post-SPMD optimized HLO with trip-count-aware accounting.

Writes experiments/roofline.json and a markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "roofline.json")


def analyze_cell(rec: Dict) -> Dict:
    chips = rec["chips"]
    ana = rec["analytic"]
    flops = ana["flops"]
    hbm = ana["bytes"]
    wire_per_chip = rec["collectives"]["wire_bytes"]

    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = hbm / (chips * HBM_BW)
    t_coll = wire_per_chip / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    step = max(terms.values())          # perfect-overlap lower bound
    mfu = (rec["model_flops"] / (chips * PEAK_FLOPS)) / step if step else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "chips": chips,
        "mesh": rec.get("mesh"),
        "terms_s": terms, "dominant": dom,
        "step_floor_s": step,
        "model_flops": rec["model_flops"],
        "analytic_flops": flops,
        "useful_flops_ratio": rec["model_flops"] / flops if flops else 0.0,
        "roofline_fraction": mfu,       # MODEL_FLOPS-based fraction of peak
        "memory_per_device": rec.get("memory"),
        "collective_counts": rec["collectives"].get("counts"),
        "cost_analysis_flops_per_dev": rec.get("cost", {}).get("flops"),
        "microbatches": rec.get("microbatches"),
    }


def bottleneck_note(cell: Dict) -> str:
    dom = cell["dominant"]
    if dom == "collective":
        return ("TP activation gathers dominate — reshard activations "
                "(head/sequence sharding) or overlap collectives with compute")
    if dom == "memory":
        return ("HBM-bound: score tensors round-trip HBM on the jnp path — "
                "the Pallas flash kernel keeps them in VMEM; or raise "
                "arithmetic intensity (larger microbatch)")
    return "compute-bound: reduce remat recompute or skip masked attention work"


def run(write: bool = True) -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(f))
        if rec.get("skipped") or not rec.get("ok"):
            continue
        cells.append(analyze_cell(rec))
    if write:
        with open(OUT_JSON, "w") as fh:
            json.dump(cells, fh, indent=2)
    return cells


def markdown_table(cells: List[Dict], pod: int = 256) -> str:
    rows = [c for c in cells if c["chips"] == pod]
    rows.sort(key=lambda c: (c["arch"], c["shape"]))
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful-FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        t = c["terms_s"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {t['compute']:.4f} | "
            f"{t['memory']:.4f} | {t['collective']:.4f} | {c['dominant']} | "
            f"{c['useful_flops_ratio']:.2f} | {c['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    cells = run()
    print(markdown_table(cells))
    worst = sorted((c for c in cells if c["chips"] == 256),
                   key=lambda c: c["roofline_fraction"])[:5]
    print("\nWorst roofline fractions (single pod):")
    for c in worst:
        print(f"  {c['arch']} {c['shape']}: {c['roofline_fraction']:.4f} "
              f"({c['dominant']}) — {bottleneck_note(c)}")


if __name__ == "__main__":
    main()
