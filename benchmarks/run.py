"""Benchmark harness — one entry per paper table/figure + framework perf.

Prints ``name,us_per_call,derived`` CSV rows:
  * campaign_replay   — paper §4/Fig 5 (duration vs 77-day actual / 58 floor)
  * route_rates       — paper Table 3 (per-route GB/s)
  * fault_stats       — paper Fig 6 (fault skew)
  * relay_vs_naive    — paper §1 relay argument (in-mesh analytic + model)
  * checksum_kernel   — integrity hash throughput (Pallas interpret vs numpy)
  * scheduler_step    — Figure-4 state machine step latency at campaign scale
  * roofline          — summary over the dry-run grid (see EXPERIMENTS.md)
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def bench_campaign_replay() -> None:
    from benchmarks.campaign_replay import replay
    t0 = time.time()
    out, rep = replay(n_datasets=573, scale=0.25, step_s=3600.0)
    us = (time.time() - t0) * 1e6
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "campaign_replay.json"), "w") as f:
        json.dump(out, f, indent=2)
    _row("campaign_replay", us,
         f"duration={out['duration_days']:.1f}d floor={out['floor_days']:.1f}d "
         f"(paper 77d/58d) complete={out['complete_at_both']}")
    _row("fault_stats", us,
         f"total={out['faults_total']} mean={out['faults_mean']} "
         f"max={out['faults_max']} (paper: 4086 total, 1.05 mean, skewed)")
    rates = " ".join(f"{k}={v}" for k, v in out["per_route_gbps"].items())
    _row("route_rates", us,
         f"GB/s {rates} (paper Table 3: 0.648/0.662/1.706/2.352)")


def bench_relay_vs_naive() -> None:
    from repro.core.relay_collectives import (estimate_naive_time,
                                              estimate_relay_time)
    bw = 50e9
    nbytes = 8 * 2 ** 30
    t0 = time.time()
    relay8 = estimate_relay_time(nbytes, bw, 8, n_chunks=16)
    naive8 = estimate_naive_time(nbytes, bw, 8)
    us = (time.time() - t0) * 1e6
    _row("relay_vs_naive", us,
         f"8-pod broadcast 8GiB: relay={relay8:.3f}s naive={naive8:.3f}s "
         f"speedup={naive8/relay8:.2f}x (paper: relay cut 2x58d to 77d)")


def bench_checksum_kernel() -> None:
    from repro.kernels.checksum.ops import checksum_bytes
    from repro.kernels.checksum.ref import checksum_bytes_np
    data = np.random.default_rng(0).bytes(4 << 20)
    t0 = time.time()
    h1 = checksum_bytes(data)          # includes jit/interpret overhead
    us_pallas = (time.time() - t0) * 1e6
    t0 = time.time()
    for _ in range(5):
        h2 = checksum_bytes_np(data)
    us_np = (time.time() - t0) * 1e6 / 5
    assert h1 == h2
    gbps = (len(data) / 2 ** 30) / (us_np / 1e6)
    _row("checksum_kernel", us_np,
         f"numpy-ref {gbps:.2f} GiB/s on 4MiB; pallas(interpret) "
         f"{us_pallas:.0f}us first-call (bit-identical)")


def bench_scheduler_step() -> None:
    from repro.core.campaign import CampaignConfig, build_campaign
    cfg = CampaignConfig(n_datasets=2291, scale=0.01, step_s=1800.0)
    (_, _, clock, _, transport, _, sched, _) = build_campaign(cfg)
    sched.step(clock.now)   # warm
    t0 = time.time()
    n = 20
    for _ in range(n):
        sched.step(clock.now)
        clock.advance(cfg.step_s)
        transport.tick()
    us = (time.time() - t0) * 1e6 / n
    _row("scheduler_step", us, "Figure-4 loop @ 2291 datasets in table")


def bench_roofline() -> None:
    t0 = time.time()
    try:
        from benchmarks.roofline import run
        cells = run(write=True)
        us = (time.time() - t0) * 1e6
        if cells:
            best = max(cells, key=lambda c: c["roofline_fraction"])
            _row("roofline", us,
                 f"{len(cells)} cells analyzed; best fraction "
                 f"{best['roofline_fraction']:.3f} "
                 f"({best['arch']} {best['shape']})")
        else:
            _row("roofline", us,
                 "no dry-run artifacts (run launch/dryrun --all)")
    except Exception as e:  # pragma: no cover
        _row("roofline", 0.0, f"skipped: {e}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_relay_vs_naive()
    bench_checksum_kernel()
    bench_scheduler_step()
    bench_campaign_replay()
    bench_roofline()


if __name__ == "__main__":
    main()
