"""CI perf gate: fail when the paper-2022 replay regresses vs the committed
baseline.

Compares a freshly measured ``BENCH_scenarios.json`` (``--candidate``)
against the repository's committed one (``--baseline``) on the
``engine_comparison`` block:

  * hard determinism invariants (machine-independent): the event-engine
    replay must reach the same iteration count, simulated duration, and
    fault totals as the baseline — a drift here means behavior changed, not
    just speed;
  * wall-clock gate: the event-engine replay may not regress more than
    ``--max-regress`` (default 0.25 = +25%) vs the baseline.  Raw wall
    clock is machine-sensitive (CI runners vs the committing machine), so
    the gate normalizes each measurement by its *own run's* step-engine
    wall clock — both engines replay the identical campaign in the same
    process, so the events/step ratio cancels machine speed and isolates
    the event engine's relative cost, which is what a perf regression
    actually moves.

    python benchmarks/check_regression.py \
        --baseline BENCH_scenarios.json --candidate BENCH_ci.json
"""
from __future__ import annotations

import argparse
import json
import sys


def check(baseline: dict, candidate: dict, max_regress: float) -> list:
    """Returns a list of failure strings (empty = pass)."""
    fails = []
    try:
        base = baseline["engine_comparison"]
        cand = candidate["engine_comparison"]
    except KeyError as e:
        return [f"missing engine_comparison block: {e}"]
    if base.get("n_datasets") != cand.get("n_datasets") or \
            base.get("seed") != cand.get("seed"):
        return [f"benchmark shapes differ: baseline "
                f"n={base.get('n_datasets')}/seed={base.get('seed')} vs "
                f"candidate n={cand.get('n_datasets')}/seed={cand.get('seed')}"]
    b_ev, c_ev = base["events"], cand["events"]
    for key in ("iterations", "duration_days", "faults_total", "quarantined"):
        if b_ev.get(key) != c_ev.get(key):
            fails.append(f"determinism drift in events.{key}: "
                         f"baseline {b_ev.get(key)} vs candidate {c_ev.get(key)}")
    # machine-normalized wall-clock: events cost as a fraction of the same
    # run's step-engine cost (the step driver replays the identical campaign,
    # so runner speed cancels out of the ratio)
    b_ratio = b_ev["wall_s"] / max(base["step"]["wall_s"], 1e-9)
    c_ratio = c_ev["wall_s"] / max(cand["step"]["wall_s"], 1e-9)
    limit = b_ratio * (1.0 + max_regress)
    if c_ratio > limit:
        fails.append(
            f"paper-2022 event replay wall-clock regressed: "
            f"events/step ratio {c_ratio:.4f} > {limit:.4f} "
            f"(baseline {b_ratio:.4f} + {max_regress:.0%}); raw "
            f"{c_ev['wall_s']:.3f}s vs baseline {b_ev['wall_s']:.3f}s)")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_scenarios.json")
    ap.add_argument("--candidate", required=True)
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed normalized wall-clock slowdown fraction "
                         "(0.25 = +25%%)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    fails = check(baseline, candidate, args.max_regress)
    for tag, doc in (("baseline ", baseline), ("candidate", candidate)):
        ec = doc.get("engine_comparison", {})
        ev, st = ec.get("events", {}), ec.get("step", {})
        print(f"{tag}: events={ev.get('wall_s')}s step={st.get('wall_s')}s "
              f"iters={ev.get('iterations')} days={ev.get('duration_days')} "
              f"faults={ev.get('faults_total')}")
    if fails:
        for f_ in fails:
            print(f"FAIL: {f_}", file=sys.stderr)
        return 1
    print(f"OK: within +{args.max_regress:.0%} of baseline normalized "
          "wall-clock, determinism invariants intact")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
