"""CI perf gate: fail when the paper-2022 replay regresses vs the committed
baseline.

Compares a freshly measured ``BENCH_scenarios.json`` (``--candidate``)
against the repository's committed one (``--baseline``) on the
``engine_comparison`` block:

  * hard determinism invariants (machine-independent): the event-engine
    replay must reach the same iteration count, simulated duration, and
    fault totals as the baseline — a drift here means behavior changed, not
    just speed;
  * wall-clock gate: the event-engine replay may not regress more than
    ``--max-regress`` (default 0.25 = +25%) vs the baseline.  Raw wall
    clock is machine-sensitive (CI runners vs the committing machine), so
    the gate normalizes each measurement by its *own run's* step-engine
    wall clock — both engines replay the identical campaign in the same
    process, so the events/step ratio cancels machine speed and isolates
    the event engine's relative cost, which is what a perf regression
    actually moves.

    python benchmarks/check_regression.py \
        --baseline BENCH_scenarios.json --candidate BENCH_ci.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _wall_gate(tag: str, base: dict, cand: dict, max_regress: float,
               fails: list) -> None:
    """Machine-normalized wall-clock: events cost as a fraction of the same
    run's step-engine cost (the step driver replays the identical campaign,
    so runner speed cancels out of the ratio)."""
    b_ev, c_ev = base["events"], cand["events"]
    b_ratio = b_ev["wall_s"] / max(base["step"]["wall_s"], 1e-9)
    c_ratio = c_ev["wall_s"] / max(cand["step"]["wall_s"], 1e-9)
    limit = b_ratio * (1.0 + max_regress)
    if c_ratio > limit:
        fails.append(
            f"{tag} event replay wall-clock regressed: "
            f"events/step ratio {c_ratio:.4f} > {limit:.4f} "
            f"(baseline {b_ratio:.4f} + {max_regress:.0%}); raw "
            f"{c_ev['wall_s']:.3f}s vs baseline {b_ev['wall_s']:.3f}s)")


def check(baseline: dict, candidate: dict, max_regress: float) -> list:
    """Returns a list of failure strings (empty = pass)."""
    fails = []
    try:
        base = baseline["engine_comparison"]
        cand = candidate["engine_comparison"]
    except KeyError as e:
        return [f"missing engine_comparison block: {e}"]
    if base.get("n_datasets") != cand.get("n_datasets") or \
            base.get("seed") != cand.get("seed"):
        return [f"benchmark shapes differ: baseline "
                f"n={base.get('n_datasets')}/seed={base.get('seed')} vs "
                f"candidate n={cand.get('n_datasets')}/seed={cand.get('seed')}"]
    b_ev, c_ev = base["events"], cand["events"]
    for key in ("iterations", "duration_days", "faults_total", "quarantined"):
        if b_ev.get(key) != c_ev.get(key):
            fails.append(f"determinism drift in events.{key}: "
                         f"baseline {b_ev.get(key)} vs candidate {c_ev.get(key)}")
    _wall_gate("paper-2022", base, cand, max_regress, fails)
    fails.extend(check_federation(baseline, candidate, max_regress))
    fails.extend(check_ensemble(baseline, candidate))
    fails.extend(check_policy(baseline, candidate))
    fails.extend(check_demand(baseline, candidate))
    fails.extend(check_integrity(baseline, candidate))
    fails.extend(check_obs(baseline, candidate))
    fails.extend(check_scaling(baseline, candidate, max_regress))
    return fails


# absolute wall budget for the full 29M-file two-destination replay (the
# acceptance criterion is "minutes on one core"; the replay takes ~5 s on a
# development machine, so 600 s leaves two orders of magnitude of headroom
# for slow CI runners while still catching an O(files) regression, which
# would blow through it immediately)
WALL_BUDGET_29M_S = 600.0


def check_scaling(baseline: dict, candidate: dict,
                  max_regress: float) -> list:
    """Scaling gate, three parts:

      * determinism: every catalog-size point of the ``scaling`` and
        ``scaling_mega-campaign`` sweeps must reproduce the baseline's
        iteration count, simulated days, and fault totals exactly;
      * flat curve: the ratio of us/iteration at the largest point to the
        smallest point (machine speed cancels out of the ratio) may not
        regress more than ``max_regress`` vs the baseline's ratio — this is
        the O(active)-not-O(catalog) property, including the mega-campaign
        point normalized against the same candidate run's smallest
        paper-2022 point;
      * wall budget: the full 29M-file ``paper-29m-twice`` replay (the
        ``profile_paper-29m-twice`` block) must complete inside
        ``WALL_BUDGET_29M_S`` — an absolute bound, deliberately loose
        enough for slow runners but far below what any O(files) path
        would cost."""
    fails = []
    base = baseline.get("scaling")
    if base is None:
        return []               # pre-scaling baseline: nothing to gate
    cand = candidate.get("scaling")
    if cand is None:
        return ["candidate is missing the scaling block "
                "(run benchmarks/campaign_replay.py --scaling)"]

    def points(doc):
        return {p["n_datasets"]: p for p in doc.get("points", [])}

    def pin_points(tag, b_pts, c_pts):
        for n, bp in sorted(b_pts.items()):
            cp = c_pts.get(n)
            if cp is None:
                fails.append(f"{tag} point n={n} missing from candidate")
                continue
            for key in ("iterations", "duration_days", "faults_total",
                        "quarantined"):
                if bp.get(key) != cp.get(key):
                    fails.append(
                        f"{tag} determinism drift at n={n}.{key}: baseline "
                        f"{bp.get(key)} vs candidate {cp.get(key)}")

    def us_per_iter(pts, n):
        return max(pts[n]["us_per_iteration"], 1e-9)

    b_pts, c_pts = points(base), points(cand)
    pin_points("scaling", b_pts, c_pts)
    shared = sorted(set(b_pts) & set(c_pts))
    if len(shared) >= 2:
        lo, hi = shared[0], shared[-1]
        b_flat = us_per_iter(b_pts, hi) / us_per_iter(b_pts, lo)
        c_flat = us_per_iter(c_pts, hi) / us_per_iter(c_pts, lo)
        limit = b_flat * (1.0 + max_regress)
        if c_flat > limit:
            fails.append(
                f"scaling curve is no longer flat: us/iteration grows "
                f"{c_flat:.3f}x from n={lo} to n={hi} "
                f"(baseline {b_flat:.3f}x + {max_regress:.0%} allowed)")
    b_mega = baseline.get("scaling_mega-campaign")
    c_mega = candidate.get("scaling_mega-campaign")
    if b_mega is not None:
        if c_mega is None:
            fails.append("candidate is missing the scaling_mega-campaign "
                         "block (run benchmarks/campaign_replay.py --scaling "
                         "--scenario mega-campaign --scaling-ns 20480)")
        else:
            bm_pts, cm_pts = points(b_mega), points(c_mega)
            pin_points("scaling_mega-campaign", bm_pts, cm_pts)
            mega = sorted(set(bm_pts) & set(cm_pts))
            if mega and shared:
                n, lo = mega[-1], shared[0]
                b_norm = us_per_iter(bm_pts, n) / us_per_iter(b_pts, lo)
                c_norm = us_per_iter(cm_pts, n) / us_per_iter(c_pts, lo)
                limit = b_norm * (1.0 + max_regress)
                if c_norm > limit:
                    fails.append(
                        f"mega-campaign us/iteration regressed: "
                        f"{c_norm:.3f}x the same run's n={lo} paper-2022 "
                        f"point (baseline {b_norm:.3f}x + "
                        f"{max_regress:.0%} allowed)")
    b_29 = baseline.get("profile_paper-29m-twice")
    if b_29 is not None:
        c_29 = candidate.get("profile_paper-29m-twice")
        if c_29 is None:
            fails.append("candidate is missing the profile_paper-29m-twice "
                         "block (run benchmarks/campaign_replay.py --profile "
                         "--scenario paper-29m-twice)")
        else:
            if b_29.get("iterations") != c_29.get("iterations"):
                fails.append(
                    f"paper-29m-twice determinism drift in iterations: "
                    f"baseline {b_29.get('iterations')} vs candidate "
                    f"{c_29.get('iterations')}")
            wall = c_29.get("wall_s", float("inf"))
            if wall > WALL_BUDGET_29M_S:
                fails.append(
                    f"the 29M-file replay blew its wall budget: "
                    f"{wall:.1f}s > {WALL_BUDGET_29M_S:.0f}s — an O(files) "
                    "path is back on the hot loop")
    return fails


def check_ensemble(baseline: dict, candidate: dict) -> list:
    """Ensemble gate: the 256-lane lockstep replay must stay bit-identical
    to the scalar engine on every sampled lane (trajectory tuple + quantile
    bands, both machine-independent), and the measured worlds/sec speedup —
    a same-process ratio, so runner speed cancels — must stay at or above
    the bench's floor (>=20x by default)."""
    fails = []
    base = baseline.get("ensemble")
    if base is None:
        return []               # pre-ensemble baseline: nothing to gate
    cand = candidate.get("ensemble")
    if cand is None:
        return ["candidate is missing the ensemble block "
                "(run benchmarks/campaign_replay.py --ensemble-bench)"]
    for key in ("ensemble", "n_lanes", "scale", "n_datasets", "sample"):
        if base.get(key) != cand.get(key):
            return [f"ensemble benchmark shapes differ on {key}: baseline "
                    f"{base.get(key)} vs candidate {cand.get(key)}"]
    for key in ("iterations", "sim_days", "faults_total", "quarantined",
                "succeeded_digest", "timed_out"):
        if base.get("lane0", {}).get(key) != cand.get("lane0", {}).get(key):
            fails.append(
                f"ensemble determinism drift in lane0.{key}: baseline "
                f"{base.get('lane0', {}).get(key)} vs candidate "
                f"{cand.get('lane0', {}).get(key)}")
    if base.get("bands") != cand.get("bands"):
        fails.append("ensemble quantile bands drifted from baseline "
                     "(the 256-lane trajectory set changed)")
    if not cand.get("lanes_identical"):
        fails.append("ensemble lanes engine diverged from the scalar "
                     f"engine: mismatches={cand.get('mismatches')}")
    if cand.get("speedup", 0.0) < cand.get("min_speedup", 20.0):
        fails.append(
            f"ensemble worlds/sec speedup fell below the floor: "
            f"{cand.get('speedup')}x < {cand.get('min_speedup')}x "
            f"(lanes {cand.get('lanes_wall_s')}s vs projected sequential "
            f"{cand.get('scalar_projected_s')}s)")
    return fails


def check_policy(baseline: dict, candidate: dict) -> list:
    """Control-plane gate: every policy-bench run (scenario × policy ×
    engine) must reproduce the baseline's determinism tuple exactly —
    iterations, float-exact simulated days, fault totals, and the
    succeeded-set digest — and the adaptive policy must finish
    ``small-file-storm`` in no more simulated campaign days than the static
    per-dataset baseline."""
    fails = []
    base = baseline.get("policy")
    if base is None:
        return []               # pre-control-plane baseline: nothing to gate
    cand = candidate.get("policy")
    if cand is None:
        return ["candidate is missing the policy block "
                "(run benchmarks/campaign_replay.py --policy-bench)"]
    if base.get("seed") != cand.get("seed") or \
            base.get("shapes") != cand.get("shapes"):
        return [f"policy benchmark shapes differ: baseline "
                f"seed={base.get('seed')}/shapes={base.get('shapes')} vs "
                f"candidate seed={cand.get('seed')}/"
                f"shapes={cand.get('shapes')}"]
    for scenario, b_block in base.get("scenarios", {}).items():
        c_block = cand.get("scenarios", {}).get(scenario)
        if c_block is None:
            fails.append(f"policy scenario {scenario!r} missing from "
                         "candidate")
            continue
        for run, b_run in b_block.items():
            if not isinstance(b_run, dict):
                continue        # the adaptive_beats_static verdict
            c_run = c_block.get(run, {})
            for key in ("iterations", "sim_days", "faults_total",
                        "quarantined", "succeeded_digest"):
                if b_run.get(key) != c_run.get(key):
                    fails.append(
                        f"policy determinism drift in "
                        f"{scenario}.{run}.{key}: baseline {b_run.get(key)} "
                        f"vs candidate {c_run.get(key)}")
    storm = cand.get("scenarios", {}).get("small-file-storm", {})
    if storm and not storm.get("adaptive_beats_static"):
        fails.append(
            "adaptive policy no longer beats the static per-dataset "
            f"baseline on small-file-storm: adaptive "
            f"{storm.get('adaptive', {}).get('sim_days')} d vs static "
            f"{storm.get('static', {}).get('sim_days')} d")
    return fails


def check_demand(baseline: dict, candidate: dict) -> list:
    """Demand-engine gate: every demand-bench arm must reproduce the
    baseline's determinism tuple exactly — iterations, float-exact simulated
    days, fault totals, the succeeded-set digest, and the serving SLOs —
    and two scenario-level invariants must hold on the candidate itself:
    popular-first replication beats catalog-order on hit-rate and
    time-to-90%-hit-rate under identical traffic, and serving the traffic
    costs at most 50% extra campaign days over the no-traffic baseline.
    The steady-state serving floor (final-day hit-rate >= 0.9) is pinned on
    the popular-first arm."""
    fails = []
    base = baseline.get("demand")
    if base is None:
        return []               # pre-demand baseline: nothing to gate
    cand = candidate.get("demand")
    if cand is None:
        return ["candidate is missing the demand block "
                "(run benchmarks/campaign_replay.py --demand-bench)"]
    if base.get("seed") != cand.get("seed") or \
            base.get("shape") != cand.get("shape"):
        return [f"demand benchmark shapes differ: baseline "
                f"seed={base.get('seed')}/shape={base.get('shape')} vs "
                f"candidate seed={cand.get('seed')}/shape={cand.get('shape')}"]
    for arm, b_arm in base.get("arms", {}).items():
        c_arm = cand.get("arms", {}).get(arm)
        if c_arm is None:
            fails.append(f"demand arm {arm!r} missing from candidate")
            continue
        for key in ("iterations", "sim_days", "faults_total", "quarantined",
                    "succeeded_digest"):
            if b_arm.get(key) != c_arm.get(key):
                fails.append(
                    f"demand determinism drift in {arm}.{key}: baseline "
                    f"{b_arm.get(key)} vs candidate {c_arm.get(key)}")
        if b_arm.get("serving") != c_arm.get("serving"):
            fails.append(
                f"demand serving-SLO drift in {arm}: baseline "
                f"{b_arm.get('serving')} vs candidate "
                f"{c_arm.get('serving')}")
    if not cand.get("popular_first_beats_catalog_order"):
        pf = cand.get("arms", {}).get("popular_first", {}).get("serving", {})
        co = cand.get("arms", {}).get("catalog_order", {}).get("serving", {})
        fails.append(
            "popular-first replication no longer beats catalog-order: "
            f"hit-rate {pf.get('hit_rate')} (day90 {pf.get('day90')}) vs "
            f"{co.get('hit_rate')} (day90 {co.get('day90')})")
    if not cand.get("traffic_tax_ok"):
        fails.append(
            "serving traffic costs more than 50% extra campaign days: "
            f"popular-first "
            f"{cand.get('arms', {}).get('popular_first', {}).get('sim_days')}"
            " d vs no-traffic "
            f"{cand.get('arms', {}).get('no_traffic', {}).get('sim_days')} d")
    floor = (cand.get("arms", {}).get("popular_first", {})
             .get("serving", {}).get("final_day_hit_rate", 0.0))
    if floor < 0.9:
        fails.append(
            "esgf-serving steady-state hit-rate fell below the 0.9 floor: "
            f"final-day hit-rate {floor}")
    return fails


def check_integrity(baseline: dict, candidate: dict) -> list:
    """Integrity gate: every integrity-bench arm must reproduce the
    baseline's determinism tuple exactly — iterations, float-exact simulated
    days, fault totals, the succeeded-set digest, the replica-set digest,
    and the full integrity summary (detections, repairs, exposure,
    surviving at-risk bytes) — and the scenario-level verdicts must hold on
    the candidate itself: scrub arms end with zero corrupt replicas, the
    repaired end state is set-identical to the corruption-free run's, the
    no-scrub ablation still surfaces surviving corruption, exposure stays
    bounded, and the repair-traffic tax stays under 75% extra campaign
    days."""
    fails = []
    base = baseline.get("integrity")
    if base is None:
        return []               # pre-scrub baseline: nothing to gate
    cand = candidate.get("integrity")
    if cand is None:
        return ["candidate is missing the integrity block "
                "(run benchmarks/campaign_replay.py --integrity-bench)"]
    if base.get("seed") != cand.get("seed") or \
            base.get("shape") != cand.get("shape"):
        return [f"integrity benchmark shapes differ: baseline "
                f"seed={base.get('seed')}/shape={base.get('shape')} vs "
                f"candidate seed={cand.get('seed')}/shape={cand.get('shape')}"]
    for arm, b_arm in base.get("arms", {}).items():
        c_arm = cand.get("arms", {}).get(arm)
        if c_arm is None:
            fails.append(f"integrity arm {arm!r} missing from candidate")
            continue
        for key in ("iterations", "sim_days", "faults_total", "quarantined",
                    "succeeded_digest", "replica_digest"):
            if b_arm.get(key) != c_arm.get(key):
                fails.append(
                    f"integrity determinism drift in {arm}.{key}: baseline "
                    f"{b_arm.get(key)} vs candidate {c_arm.get(key)}")
        if b_arm.get("integrity") != c_arm.get("integrity"):
            fails.append(
                f"integrity summary drift in {arm}: baseline "
                f"{b_arm.get('integrity')} vs candidate "
                f"{c_arm.get('integrity')}")
    for verdict, msg in (
            ("ends_clean", "a scrub arm no longer ends corruption-free "
                           "(zero detected, or surviving corrupt replicas)"),
            ("repairs_converge", "the scrub arm's final replica set no "
                                 "longer matches the corruption-free run's"),
            ("ablation_survives_corrupt",
             "the no-scrub ablation no longer surfaces surviving "
             "corruption — the injector may have stopped drawing"),
            ("exposure_ok", "at-risk exposure exceeded 3 scrub intervals "
                            "per detected replica"),
            ("repair_tax_ok", "scrub + repair cost more than 75% extra "
                              "campaign days over the corruption-free "
                              "baseline")):
        if not cand.get(verdict):
            sr = cand.get("arms", {}).get("scrub_repair", {})
            fails.append(
                f"integrity verdict {verdict} failed: {msg} "
                f"(scrub_repair sim_days="
                f"{sr.get('sim_days')}, integrity={sr.get('integrity')})")
    return fails


def check_obs(baseline: dict, candidate: dict) -> list:
    """Flight-recorder gate: the obs-on paper-2022 replay must stay within
    the bench's own overhead budget (wall ratio obs_on/obs_off, measured
    in-process so machine speed cancels), the obs-on and obs-off trajectory
    tuples must be identical to each other (the bit-identity contract), and
    both arms must reproduce the baseline's trajectory exactly."""
    fails = []
    base = baseline.get("obs")
    if base is None:
        return []               # pre-obs baseline: nothing to gate
    cand = candidate.get("obs")
    if cand is None:
        return ["candidate is missing the obs block "
                "(run benchmarks/campaign_replay.py --obs-bench)"]
    if base.get("n_datasets") != cand.get("n_datasets") or \
            base.get("seed") != cand.get("seed") or \
            base.get("scale") != cand.get("scale"):
        return [f"obs benchmark shapes differ: baseline "
                f"n={base.get('n_datasets')}/seed={base.get('seed')}"
                f"/scale={base.get('scale')} vs candidate "
                f"n={cand.get('n_datasets')}/seed={cand.get('seed')}"
                f"/scale={cand.get('scale')}"]
    if not cand.get("obs_identical"):
        fails.append(
            "obs bit-identity contract broken: the obs-on trajectory "
            f"differs from obs-off (on={cand.get('obs_on', {}).get('trajectory')} "
            f"vs off={cand.get('obs_off', {}).get('trajectory')})")
    for arm in ("obs_off", "obs_on"):
        b_t = base.get(arm, {}).get("trajectory")
        c_t = cand.get(arm, {}).get("trajectory")
        if b_t != c_t:
            fails.append(f"obs determinism drift in {arm}: baseline "
                         f"{b_t} vs candidate {c_t}")
    limit = cand.get("max_overhead", base.get("max_overhead", 1.10))
    ratio = cand.get("overhead_ratio")
    if ratio is None or ratio > limit:
        fails.append(
            f"obs overhead gate failed: obs-on/obs-off wall ratio "
            f"{ratio} > {limit} "
            f"(on={cand.get('obs_on', {}).get('wall_s')}s vs "
            f"off={cand.get('obs_off', {}).get('wall_s')}s)")
    return fails


def check_federation(baseline: dict, candidate: dict,
                     max_regress: float) -> list:
    """Federation gate: the overlapped two-campaign replay is held to the
    same standard as paper-2022 — exact determinism (iterations, span,
    faults, per-member digests), the shared source-egress cap, the
    overlap-beats-serial property, and the normalized wall-clock limit."""
    fails = []
    base = baseline.get("federation")
    if base is None:
        return []               # pre-federation baseline: nothing to gate
    cand = candidate.get("federation")
    if cand is None:
        return ["candidate is missing the federation block "
                "(run benchmarks/campaign_replay.py --federation-bench)"]
    if base.get("n_datasets") != cand.get("n_datasets") or \
            base.get("seed") != cand.get("seed"):
        return [f"federation benchmark shapes differ: baseline "
                f"n={base.get('n_datasets')}/seed={base.get('seed')} vs "
                f"candidate n={cand.get('n_datasets')}/seed={cand.get('seed')}"]
    b_ev, c_ev = base["events"], cand["events"]
    for key in ("iterations", "span_days", "faults_total"):
        if b_ev.get(key) != c_ev.get(key):
            fails.append(f"federation determinism drift in events.{key}: "
                         f"baseline {b_ev.get(key)} vs "
                         f"candidate {c_ev.get(key)}")
    for label, member in b_ev.get("members", {}).items():
        got = c_ev.get("members", {}).get(label, {})
        if member.get("succeeded_digest") != got.get("succeeded_digest"):
            fails.append(f"federation member {label!r} digest drift: "
                         f"{member.get('succeeded_digest')} vs "
                         f"{got.get('succeeded_digest')}")
    for engine in ("events", "step"):
        if not cand[engine].get("source_cap_ok"):
            fails.append(f"federation {engine} replay exceeded the shared "
                         "LLNL source read cap "
                         f"(max {cand[engine].get('source_cap_max_frac')}x)")
    if not cand.get("overlap_beats_serial"):
        fails.append(
            f"overlapped federation no longer beats the serial variant: "
            f"span {c_ev.get('span_days')} d vs serial "
            f"{cand.get('serial_span_days')} d")
    _wall_gate("federation-paper-twice", base, cand, max_regress, fails)
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_scenarios.json")
    ap.add_argument("--candidate", required=True)
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed normalized wall-clock slowdown fraction "
                         "(0.25 = +25%%)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    fails = check(baseline, candidate, args.max_regress)
    for tag, doc in (("baseline ", baseline), ("candidate", candidate)):
        ec = doc.get("engine_comparison", {})
        ev, st = ec.get("events", {}), ec.get("step", {})
        print(f"{tag}: events={ev.get('wall_s')}s step={st.get('wall_s')}s "
              f"iters={ev.get('iterations')} days={ev.get('duration_days')} "
              f"faults={ev.get('faults_total')}")
    if fails:
        for f_ in fails:
            print(f"FAIL: {f_}", file=sys.stderr)
        return 1
    print(f"OK: within +{args.max_regress:.0%} of baseline normalized "
          "wall-clock, determinism invariants intact")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
